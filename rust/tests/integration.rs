//! Cross-module integration tests (`cargo test --test integration`).
//!
//! The PJRT tests are gated on the `pjrt` cargo feature *and* on
//! `artifacts/manifest.json` existing (built by the python layer);
//! everything else runs standalone on the std-only build.

use std::sync::Arc;

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Cld, Process, TimeGrid, Vpsde};
use gddim::engine::{Engine, EngineConfig, Job};
use gddim::samplers::{Ancestral, GddimDet};
use gddim::math::rng::Rng;
use gddim::metrics::coverage::coverage;
use gddim::metrics::frechet::frechet_to_spec;
use gddim::metrics::wasserstein::sliced_w1;
use gddim::score::oracle::GmmOracle;

/// Full-stack smoke without PJRT: plan → sample → metric, both processes.
#[test]
fn end_to_end_oracle_pipeline() {
    for (proc, dataset) in [("vpsde", "gmm2d"), ("cld", "gmm2d")] {
        let spec = presets::by_name(dataset).unwrap();
        let p: Arc<dyn Process> = match proc {
            "vpsde" => Arc::new(Vpsde::standard(spec.d)),
            _ => Arc::new(Cld::standard(spec.d)),
        };
        let oracle = GmmOracle::new(p.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 30);
        let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let mut rng = Rng::seed_from(1);
        let out = gddim::samplers::gddim::sample_deterministic(
            p.as_ref(),
            &plan,
            &oracle,
            1500,
            &mut rng,
            false,
        );
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.5, "{proc}: FD {fd}");
    }
}

/// Determinism across identical runs (same seed ⇒ identical samples).
#[test]
fn sampling_is_reproducible() {
    let spec = presets::gmm2d();
    let p = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(p.clone(), spec, KtKind::R);
    let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 10);
    let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let run = || {
        let mut rng = Rng::seed_from(42);
        gddim::samplers::gddim::sample_deterministic(
            p.as_ref(),
            &plan,
            &oracle,
            64,
            &mut rng,
            false,
        )
        .xs
    };
    assert_eq!(run(), run());
}

/// Golden-value regression for `sample_deterministic` on the GMM oracle:
/// a fixed seed must keep landing inside fixed Fréchet/Wasserstein/mode
/// bounds. This is the tripwire for silent numeric drift anywhere in
/// Stage I or Stage II — the bounds are several × tighter than "worked at
/// all" but loose enough to survive libm differences across platforms.
#[test]
fn gddim_golden_regression_on_gmm_oracle() {
    let spec = presets::gmm2d();
    let p = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(p.clone(), spec.clone(), KtKind::R);
    let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 25);
    let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let mut rng = Rng::seed_from(0x601D);
    let out = gddim::samplers::gddim::sample_deterministic(
        p.as_ref(),
        &plan,
        &oracle,
        4000,
        &mut rng,
        false,
    );
    assert_eq!(out.nfe, 25);

    let fd = frechet_to_spec(&out.xs, &spec);
    assert!(fd < 0.35, "golden FD bound blown: {fd}");

    // Sliced W1 against fresh ground-truth draws (sees mode structure FD
    // cannot).
    let mut rng_truth = Rng::seed_from(0x7247);
    let truth = spec.sample(4000, &mut rng_truth);
    let w1 = sliced_w1(&out.xs, &truth, spec.d, 32, &mut rng_truth);
    assert!(w1 < 0.5, "golden sliced-W1 bound blown: {w1}");

    // All 8 modes present, essentially no off-manifold mass.
    let cov = coverage(&out.xs, &spec);
    assert_eq!(cov.missing, 0, "mode dropped under fixed seed");
    assert!(cov.outliers < 0.02, "outlier mass {}", cov.outliers);
}

/// Pool size the concurrency-heavy tests use. Defaults to 2 (the
/// small-pool path) so the plain `cargo test -q` CI pass and the second
/// pass with `GDDIM_TEST_WORKERS=4` exercise different contention
/// regimes — keep in sync with `engine::tests::test_workers`.
fn test_workers() -> usize {
    std::env::var("GDDIM_TEST_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2)
}

/// The engine acceptance contract, end to end: merged output bit-identical
/// across 1/2/4/8-worker pools (and the CI-selected pool size) on a fixed
/// seed. 1 worker is the inline no-pool path, so this also locks pooled
/// execution to the pre-pool implementation's bytes.
#[test]
fn engine_is_worker_count_invariant() {
    let spec = presets::gmm2d();
    let p = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(p.clone(), spec, KtKind::R);
    let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 12);
    let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let sampler = GddimDet { plan: &plan };
    let run = |workers: usize| {
        let cfg = EngineConfig { workers, shard_size: 128, ..EngineConfig::default() };
        Engine::with_config(cfg).run(&Job {
            proc: p.as_ref(),
            model: &oracle,
            sampler: &sampler,
            n: 1000,
            seed: 7,
        })
    };
    let a = run(1);
    for workers in [2usize, 4, 8, test_workers()] {
        let b = run(workers);
        assert_eq!(a.xs, b.xs, "xs diverged at {workers} workers");
        assert_eq!(a.us, b.us, "us diverged at {workers} workers");
        assert_eq!(a.nfe, b.nfe);
    }
}

/// Pool reuse across jobs: one long-lived engine serving many jobs
/// back-to-back must give each job the same bytes as a fresh
/// single-worker engine (no RNG/state leakage between jobs, no lost or
/// duplicated shards).
#[test]
fn persistent_pool_is_stateless_across_jobs() {
    let spec = presets::gmm2d();
    let p = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(p.clone(), spec, KtKind::R);
    let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 8);
    let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
    let pooled = Engine::with_config(EngineConfig {
        workers: test_workers(),
        shard_size: 64,
        ..EngineConfig::default()
    });
    let sampler = GddimDet { plan: &plan };
    for seed in 0..12u64 {
        let make = || Job {
            proc: p.as_ref(),
            model: &oracle,
            sampler: &sampler,
            n: 200,
            seed,
        };
        let fresh =
            Engine::with_config(EngineConfig { workers: 1, shard_size: 64, ..Default::default() });
        assert_eq!(
            pooled.run(&make()).xs,
            fresh.run(&make()).xs,
            "job seed {seed} differs between pooled and fresh engines"
        );
    }
    let stats = pooled.stats();
    assert_eq!(stats.jobs_run, 12);
    assert_eq!(stats.shards_executed, 12 * 4, "200 samples / 64 per shard = 4 shards per job");
}

/// Sampler-level consistency: on the exact oracle, deterministic gDDIM
/// and generalized ancestral sampling target the same data distribution,
/// so their sample means must agree (Prop. 1/2 territory — gDDIM's
/// marginal matching). Checked on both VPSDE and CLD.
#[test]
fn gddim_and_ancestral_agree_on_the_mean() {
    let spec = presets::gmm2d();
    let n = 4000;
    for proc_name in ["vpsde", "cld"] {
        let p: Arc<dyn Process> = match proc_name {
            "vpsde" => Arc::new(Vpsde::standard(spec.d)),
            _ => Arc::new(Cld::standard(spec.d)),
        };
        let oracle = GmmOracle::new(p.clone(), spec.clone(), KtKind::R);
        let engine = Engine::with_config(EngineConfig {
            workers: 2,
            shard_size: 1024,
            ..EngineConfig::default()
        });
        let grid_g = TimeGrid::uniform(p.t_min(), p.t_max(), 30);
        let plan =
            SamplerPlan::build(p.as_ref(), &grid_g, &PlanConfig::deterministic(2, KtKind::R));
        let out_gddim = engine.run(&Job {
            proc: p.as_ref(),
            model: &oracle,
            sampler: &GddimDet { plan: &plan },
            n,
            seed: 0xA11CE,
        });
        let grid_a = TimeGrid::uniform(p.t_min(), p.t_max(), 120);
        let out_ancestral = engine.run(&Job {
            proc: p.as_ref(),
            model: &oracle,
            sampler: &Ancestral { grid: &grid_a },
            n,
            seed: 0xB0B,
        });
        let mg = gddim::math::stats::mean(&out_gddim.xs, spec.d);
        let ma = gddim::math::stats::mean(&out_ancestral.xs, spec.d);
        // Bound: ≈4σ of the two-sample mean-difference noise at n=4000
        // (per-dim std ≈ 2.8), while a single dropped mode would shift a
        // mean by ~0.5 — well outside it.
        for dim in 0..spec.d {
            assert!(
                (mg[dim] - ma[dim]).abs() < 0.3,
                "{proc_name} dim {dim}: gddim mean {} vs ancestral mean {}",
                mg[dim],
                ma[dim]
            );
        }
    }
}

/// The server serves PJRT-free oracle traffic correctly under load.
#[test]
fn server_under_mixed_load() {
    use gddim::server::batcher::BatcherConfig;
    use gddim::server::request::{GenRequest, PlanKey};
    use gddim::server::router::{oracle_factory, Router};
    let router = Router::new(4, BatcherConfig::default(), oracle_factory());
    let keys = [
        PlanKey::gddim("vpsde", "gmm2d", 10, 2),
        PlanKey::gddim("cld", "gmm2d", 10, 2),
        PlanKey::gddim("cld", "hard2d", 20, 1),
    ];
    let mut rxs = Vec::new();
    for id in 0..30u64 {
        let key = keys[id as usize % keys.len()].clone();
        rxs.push((id, router.submit(GenRequest { id, n: 16, key, seed: id })));
    }
    for (id, rx) in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.xs.len(), 16 * resp.dim_x);
        assert!(resp.xs.iter().all(|x| x.is_finite()));
    }
    router.shutdown();
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use gddim::runtime::{Manifest, NetScore};
    use gddim::score::model::ScoreModel;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Manifest::default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// PJRT: every exported model loads, compiles, and reproduces the
    /// jax-recorded probe row bit-near-exactly.
    #[test]
    fn pjrt_models_match_manifest_probes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping (no artifacts; run `make artifacts`)");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        assert!(!manifest.models.is_empty());
        let client = xla::PjRtClient::cpu().unwrap();
        for entry in &manifest.models {
            let net = NetScore::load(&client, entry).unwrap();
            let err = net.probe_error().unwrap();
            assert!(err < 1e-3, "{}: probe error {err}", entry.name);
        }
    }

    /// PJRT: learned-score sampling produces usable samples (quality
    /// sanity, not paper-grade — nets are small and trained briefly).
    #[test]
    fn pjrt_learned_score_sampling_works() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping (no artifacts)");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let Some(entry) = manifest.get("vpsde_gmm2d") else {
            eprintln!("skipping (vpsde_gmm2d not exported)");
            return;
        };
        let client = xla::PjRtClient::cpu().unwrap();
        let net = NetScore::load(&client, entry).unwrap();
        let spec = presets::gmm2d();
        let p = Arc::new(Vpsde::standard(spec.d));
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 30);
        let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let mut rng = Rng::seed_from(3);
        let out = gddim::samplers::gddim::sample_deterministic(
            p.as_ref(),
            &plan,
            &net as &dyn ScoreModel,
            512,
            &mut rng,
            false,
        );
        let fd = frechet_to_spec(&out.xs, &spec);
        // Generous bound: small net, short training. The oracle scores ~0.02.
        assert!(fd < 8.0, "learned-score FD suspiciously bad: {fd}");
        let cov = coverage(&out.xs, &spec);
        assert!(cov.missing <= 2, "learned net dropped {} modes", cov.missing);
    }
}
