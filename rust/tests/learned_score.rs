//! End-to-end suite for the pure-Rust learned-score backend
//! (`cargo test --test learned_score`; CI also runs it under
//! `GDDIM_TEST_WORKERS=4`).
//!
//! Everything runs against the committed tiny-model fixture under
//! `tests/fixtures/learned/` (exported by `python -m compile.fixture`,
//! so these tests are hermetic — no JAX in the loop):
//!
//! 1. probe parity — every manifest entry's frozen `(probe_t,
//!    probe_u_row0) → probe_eps_row0` row replays through
//!    [`ScoreNet::eps`] within the 1e-6 float64-reference gate;
//! 2. `eps_batch` is bit-identical to row-by-row `eps` at n ∈ {1, 3, 33}
//!    (the row-independence contract the score scheduler pools on);
//! 3. the router serves learned `PlanKey`s end-to-end through
//!    `learned_factory`, falling back to the oracle for keys the
//!    manifest doesn't cover;
//! 4. the TCP edge (`gddim serve --models-dir`) round-trips a learned
//!    key over a real loopback socket.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use gddim::score::net::PROBE_TOL;
use gddim::score::{ModelRegistry, ScoreModel};
use gddim::server::batcher::BatcherConfig;
use gddim::server::router::learned_factory;
use gddim::server::wire::{WireRequest, WireResponse};
use gddim::server::{GenRequest, NetConfig, NetServer, PlanKey, Router};

const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/learned");

fn registry() -> ModelRegistry {
    ModelRegistry::open(FIXTURE).expect("committed fixture manifest loads")
}

/// Deterministic but non-trivial state rows (no RNG: the values only
/// need to be fixed and finite, and cover sign changes / magnitudes).
fn probe_rows(n: usize, d: usize) -> Vec<f64> {
    (0..n * d).map(|i| ((i as f64) * 0.37 + 0.11).sin() * 2.5).collect()
}

#[test]
fn every_fixture_entry_replays_its_probe_within_tolerance() {
    let reg = registry();
    assert_eq!(reg.manifest().models.len(), 2, "fixture ships two tiny models");
    for entry in &reg.manifest().models {
        let net = reg.get(&entry.name).expect("fixture weights load");
        // `ScoreNet::load` already gates on this; re-assert explicitly so
        // a loosened gate can't silently pass the suite.
        let err = net.probe_error(entry);
        assert!(err < PROBE_TOL, "{}: probe error {err:.3e} ≥ {PROBE_TOL:.0e}", entry.name);
        let eps = net.eps(entry.probe_t, &entry.probe_u_row0);
        assert_eq!(eps.len(), entry.dim_u, "{}: probe output shape", entry.name);
        for (k, (got, want)) in eps.iter().zip(&entry.probe_eps_row0).enumerate() {
            assert!(
                (got - want).abs() < PROBE_TOL,
                "{}: probe component {k}: got {got}, manifest says {want}",
                entry.name
            );
        }
    }
}

#[test]
fn eps_batch_is_bit_identical_to_row_by_row_eps() {
    let reg = registry();
    for entry in &reg.manifest().models {
        let net = reg.get(&entry.name).unwrap();
        let d = net.dim_u();
        for n in [1usize, 3, 33] {
            let us = probe_rows(n, d);
            let mut pooled = vec![0.0; n * d];
            net.eps_batch(entry.probe_t, &us, &mut pooled);
            for row in 0..n {
                let single = net.eps(entry.probe_t, &us[row * d..(row + 1) * d]);
                for k in 0..d {
                    assert_eq!(
                        pooled[row * d + k].to_bits(),
                        single[k].to_bits(),
                        "{}: n={n} row {row} component {k} not bit-identical",
                        entry.name
                    );
                }
            }
        }
    }
}

#[test]
fn router_serves_learned_keys_and_falls_back_for_uncovered_ones() {
    let factory = learned_factory(FIXTURE).expect("fixture factory");
    let router = Router::new(2, BatcherConfig::default(), factory);
    // Both fixture processes (vpsde dim_u=2, cld dim_u=4) route to the
    // learned backend; gmm2d is a 2-D dataset so x-space stays 2 wide.
    for (id, process) in [(0u64, "vpsde"), (1, "cld")] {
        let key = PlanKey::gddim(process, "gmm2d", 8, 2);
        let rx = router.submit(GenRequest { id, n: 8, key, seed: 42 + id });
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{process} learned key rejected: {:?}", resp.error);
        assert_eq!(resp.xs.len(), 8 * 2, "{process}: sample shape");
        assert!(resp.xs.iter().all(|x| x.is_finite()), "{process}: non-finite samples");
        assert!(resp.nfe > 0, "{process}: NFE not reported");
    }
    // blobs8 has no manifest entry: the factory must fall back to the
    // oracle instead of rejecting, so --models-dir never shrinks the
    // servable key space.
    let key = PlanKey::gddim("vpsde", "blobs8", 6, 2);
    let rx = router.submit(GenRequest { id: 9, n: 4, key, seed: 1 });
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(resp.error.is_none(), "uncovered key must fall back: {:?}", resp.error);
    assert_eq!(resp.xs.len(), 4 * 64);
    router.shutdown();
}

/// Same submissions, learned backend vs learned backend across router
/// instances: the registry memoizes one session per model, and sampling
/// is deterministic given (key, seed), so two routers over the same
/// fixture must agree bit for bit.
#[test]
fn learned_serving_is_deterministic_across_router_instances() {
    let sample = || {
        let router =
            Router::new(2, BatcherConfig::default(), learned_factory(FIXTURE).unwrap());
        let key = PlanKey::gddim("cld", "gmm2d", 8, 2);
        let rx = router.submit(GenRequest { id: 0, n: 16, key, seed: 7 });
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        router.shutdown();
        resp.xs
    };
    let a = sample();
    let b = sample();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "sample {i} diverged across instances");
    }
}

/// The `gddim serve --models-dir` acceptance path: a learned key served
/// over a real loopback socket through `NetServer`, answered with finite
/// samples of the right shape.
#[test]
fn tcp_edge_serves_a_learned_key() {
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetConfig::default(),
        Router::new(2, BatcherConfig::default(), learned_factory(FIXTURE).unwrap()),
    )
    .unwrap();
    let addr = server.local_addr();

    let mut conn = TcpStream::connect(addr).unwrap();
    let req =
        WireRequest { id: 5, n: 12, seed: 99, key: PlanKey::gddim("vpsde", "gmm2d", 8, 2) };
    conn.write_all(req.to_line().as_bytes()).unwrap();
    let mut lines = BufReader::new(conn).lines();
    let resp = loop {
        let line = lines.next().expect("connection closed early").expect("socket read");
        let resp = WireResponse::parse_line(&line).expect("server line must parse");
        if !matches!(resp, WireResponse::Status { .. }) {
            break resp;
        }
    };
    match resp {
        WireResponse::Result { id, xs, nfe, .. } => {
            assert_eq!(id, 5);
            assert_eq!(xs.len(), 12 * 2, "learned key over TCP: sample shape");
            assert!(xs.iter().all(|x| x.is_finite()));
            assert!(nfe > 0);
        }
        other => panic!("expected a result line, got {other:?}"),
    }
    server.shutdown();
}
