//! End-to-end tests for the TCP serving edge (`gddim serve --listen`):
//! bit-identity with the in-process router, shed-with-`Retry-After`
//! under overload, graceful drain, and malformed-line isolation — the
//! lifecycle guarantees `server::net` documents, checked over real
//! loopback sockets.

use std::io::{BufRead, BufReader, Lines, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use gddim::coeffs::plan::SamplerPlan;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Process, TimeGrid, Vpsde};
use gddim::samplers::{OrderedF64, SamplerSpec};
use gddim::score::ScoreModel;
use gddim::server::batcher::BatcherConfig;
use gddim::server::router::{oracle_factory, Prepared, PreparedFactory};
use gddim::server::wire::{WireRequest, WireResponse};
use gddim::server::{GenRequest, NetConfig, NetServer, PlanKey, Router};

/// Next substantive line: status acknowledgements are skipped, anything
/// unparseable is a test failure.
fn next_response(lines: &mut Lines<BufReader<TcpStream>>) -> WireResponse {
    loop {
        let line = lines.next().expect("connection closed early").expect("socket read");
        let resp = WireResponse::parse_line(&line).expect("server line must parse");
        if !matches!(resp, WireResponse::Status { .. }) {
            return resp;
        }
    }
}

/// An ε-model that sleeps a fixed time per call, so requests stay
/// in-flight long enough for the overload and drain tests to act while
/// the router is genuinely busy.
struct SleepyModel {
    d: usize,
    pause: Duration,
}

impl ScoreModel for SleepyModel {
    fn dim_u(&self) -> usize {
        self.d
    }

    fn kt_kind(&self) -> KtKind {
        KtKind::R
    }

    fn eps_batch(&self, _t: f64, _us: &[f64], out: &mut [f64]) {
        std::thread::sleep(self.pause);
        out.fill(0.0);
    }
}

fn sleepy_factory(pause: Duration) -> Box<PreparedFactory> {
    Box::new(move |key: &PlanKey, _preloaded| {
        let proc = Arc::new(Vpsde::standard(2));
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), key.nfe);
        let cfg = key.spec.plan_config().ok_or("test factory serves gddim keys only")?;
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &cfg);
        Ok(Arc::new(Prepared {
            dim_x: proc.dim_x(),
            model: Arc::new(SleepyModel { d: proc.dim_u(), pause }),
            plan: Some(Arc::new(plan)),
            grid,
            proc,
        }))
    })
}

#[test]
fn concurrent_tcp_clients_match_in_process_router_bit_for_bit() {
    // One key per client: the batcher groups by key, so each TCP request
    // forms its own single-member batch — exactly the shape of a lone
    // in-process submit, including the RNG fold over batch members.
    let keys = [
        PlanKey::gddim("cld", "gmm2d", 6, 1),
        PlanKey::gddim("cld", "gmm2d", 6, 2),
        PlanKey::gddim("cld", "gmm2d", 6, 3),
        PlanKey::new("cld", "gmm2d", SamplerSpec::Em { lambda: OrderedF64::new(0.0) }, 6),
    ];
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { conn_threads: keys.len(), ..NetConfig::default() },
        Router::new(2, BatcherConfig::default(), oracle_factory()),
    )
    .unwrap();
    let addr = server.local_addr();

    let tcp: Vec<(u64, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = keys
            .iter()
            .enumerate()
            .map(|(i, key)| {
                let key = key.clone();
                scope.spawn(move || {
                    let mut conn = TcpStream::connect(addr).unwrap();
                    let req = WireRequest { id: i as u64, n: 24, seed: 7 + i as u64, key };
                    conn.write_all(req.to_line().as_bytes()).unwrap();
                    let mut lines = BufReader::new(conn).lines();
                    match next_response(&mut lines) {
                        WireResponse::Result { id, xs, .. } => (id, xs),
                        other => panic!("expected a result line, got {other:?}"),
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let local = Router::new(2, BatcherConfig::default(), oracle_factory());
    for (i, key) in keys.iter().enumerate() {
        let req = GenRequest { id: i as u64, n: 24, key: key.clone(), seed: 7 + i as u64 };
        let resp = local.submit(req).recv().unwrap();
        assert!(resp.error.is_none(), "in-process baseline failed: {:?}", resp.error);
        let (_, xs) = tcp.iter().find(|(id, _)| *id == i as u64).expect("every client answered");
        assert_eq!(xs.len(), resp.xs.len(), "key {i}: sample counts differ");
        for (j, (a, b)) in xs.iter().zip(&resp.xs).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "key {i} sample {j}: TCP result must be bit-identical to Router::submit"
            );
        }
    }
    local.shutdown();

    let report = server.shutdown();
    let edge = report.edge.expect("NetServer reports carry edge counters");
    assert_eq!(edge.requests_admitted, keys.len() as u64);
    assert_eq!(edge.requests_completed, keys.len() as u64);
    assert_eq!(edge.requests_shed, 0);
    assert_eq!(edge.requests_malformed, 0);
}

#[test]
fn overload_sheds_with_retry_after_and_recovers() {
    // Watermark of 1 + a slow backend: the second request on the wire
    // must be refused with a Retry-After hint while the first is still
    // in flight, and the edge must serve normally again afterwards.
    let router = Router::new(
        1,
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(1) },
        sleepy_factory(Duration::from_millis(20)),
    );
    let cfg = NetConfig { conn_threads: 2, max_inflight: 1, slo_ms: 25, ..NetConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", cfg, router).unwrap();
    let key = PlanKey::gddim("vpsde", "gmm2d", 4, 1);
    let mk = |id: u64| WireRequest { id, n: 2, seed: id, key: key.clone() }.to_line();

    let conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = conn.try_clone().unwrap();
    // Both lines land back-to-back; the reader admits 1, then sheds 2.
    w.write_all(format!("{}{}", mk(1), mk(2)).as_bytes()).unwrap();
    let mut lines = BufReader::new(conn).lines();
    match next_response(&mut lines) {
        WireResponse::Error { id, error, retry_after_ms } => {
            assert_eq!(id, 2, "the over-watermark request is the one shed");
            assert!(error.contains("overloaded"), "{error}");
            let hint = retry_after_ms.expect("sheds carry a Retry-After hint");
            assert!(hint >= 25, "hint {hint} ms derives from the SLO window");
        }
        other => panic!("expected a shed, not a hang or {other:?}"),
    }
    match next_response(&mut lines) {
        WireResponse::Result { id: 1, xs, .. } => assert_eq!(xs.len(), 4),
        other => panic!("admitted request must still complete, got {other:?}"),
    }
    // Shedding is per-request, not per-connection: the same socket is
    // served normally once the load clears.
    w.write_all(mk(3).as_bytes()).unwrap();
    match next_response(&mut lines) {
        WireResponse::Result { id: 3, .. } => {}
        other => panic!("edge must recover after the shed, got {other:?}"),
    }

    let report = server.shutdown();
    let edge = report.edge.unwrap();
    assert_eq!(edge.requests_admitted, 2);
    assert_eq!(edge.requests_shed, 1);
    assert_eq!(edge.requests_completed, 2);
}

#[test]
fn graceful_drain_completes_in_flight_requests() {
    let router = Router::new(
        1,
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(1) },
        sleepy_factory(Duration::from_millis(10)),
    );
    let cfg = NetConfig { conn_threads: 1, ..NetConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", cfg, router).unwrap();
    let key = PlanKey::gddim("vpsde", "gmm2d", 4, 1);

    let conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = conn.try_clone().unwrap();
    let mut body = String::new();
    for id in 0..3u64 {
        body.push_str(&WireRequest { id, n: 2, seed: id, key: key.clone() }.to_line());
    }
    w.write_all(body.as_bytes()).unwrap();
    // All three must be on the books before the drain starts.
    let mut lines = BufReader::new(conn).lines();
    let mut accepted = 0;
    while accepted < 3 {
        let line = lines.next().unwrap().unwrap();
        match WireResponse::parse_line(&line).unwrap() {
            WireResponse::Status { .. } => accepted += 1,
            other => panic!("unexpected pre-drain line: {other:?}"),
        }
    }
    // Shutdown concurrently with the client still reading: drain means
    // every admitted request reaches the wire before the edge joins.
    let drain = std::thread::spawn(move || server.shutdown());
    let mut got = [false; 3];
    for _ in 0..3 {
        match next_response(&mut lines) {
            WireResponse::Result { id, xs, .. } => {
                assert_eq!(xs.len(), 4, "request {id}: n=2 × dim 2");
                got[id as usize] = true;
            }
            other => panic!("drain must answer in-flight requests, got {other:?}"),
        }
    }
    assert!(got.iter().all(|&g| g), "each of the three requests got its own result");
    let report = drain.join().unwrap();
    let edge = report.edge.unwrap();
    assert_eq!(edge.requests_admitted, 3);
    assert_eq!(edge.requests_completed, 3);
    assert_eq!(edge.requests_shed, 0);
}

#[test]
fn oversized_frames_are_bounded_answered_and_the_connection_survives() {
    // A 512-byte frame cap on the edge: both oversized shapes — a
    // complete line over the cap, and a giant never-ending line that
    // must be cut off mid-accumulation — get one error line each, the
    // reader's buffer stays bounded, and the connection keeps serving.
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { conn_threads: 1, max_frame_len: 512, ..NetConfig::default() },
        Router::new(1, BatcherConfig::default(), oracle_factory()),
    )
    .unwrap();
    let conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = conn.try_clone().unwrap();
    let mut lines = BufReader::new(conn).lines();

    // Shape 1: a complete, parseable line just over the cap.
    let line1 = format!("{{\"id\":9,\"pad\":\"{}\"}}\n", "x".repeat(600));
    w.write_all(line1.as_bytes()).unwrap();
    match next_response(&mut lines) {
        WireResponse::Error { error, retry_after_ms, .. } => {
            assert!(error.contains("max-frame"), "{error}");
            assert_eq!(retry_after_ms, None, "an oversized frame is a client bug, not a shed");
        }
        other => panic!("expected an oversized-frame error, got {other:?}"),
    }

    // Shape 2: 64 KiB without a newline — far past anything the reader
    // may buffer. Exactly one error, then the tail is discarded up to
    // the newline that restores framing.
    let mut giant = vec![b'y'; 64 * 1024];
    giant.push(b'\n');
    w.write_all(&giant).unwrap();
    match next_response(&mut lines) {
        WireResponse::Error { error, .. } => assert!(error.contains("max-frame"), "{error}"),
        other => panic!("expected an oversized-frame error, got {other:?}"),
    }

    // The same socket still serves a well-formed request.
    let req = WireRequest { id: 10, n: 3, seed: 0, key: PlanKey::gddim("vpsde", "gmm2d", 5, 1) };
    w.write_all(req.to_line().as_bytes()).unwrap();
    match next_response(&mut lines) {
        WireResponse::Result { id, dim_x, xs, .. } => {
            assert_eq!((id, dim_x), (10, 2));
            assert_eq!(xs.len(), 3 * 2);
        }
        other => panic!("expected a result after the oversized lines, got {other:?}"),
    }

    let report = server.shutdown();
    let edge = report.edge.unwrap();
    assert_eq!(edge.requests_oversized, 2, "one error per oversized line, never more");
    assert_eq!(edge.requests_malformed, 0, "oversized is its own counter");
    assert_eq!(edge.requests_admitted, 1);
    assert_eq!(edge.requests_completed, 1);
}

#[test]
fn malformed_line_is_answered_and_the_connection_survives() {
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { conn_threads: 1, ..NetConfig::default() },
        Router::new(1, BatcherConfig::default(), oracle_factory()),
    )
    .unwrap();
    let conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = conn.try_clone().unwrap();
    // Valid JSON, invalid request: the id is still recoverable, so the
    // error line carries it back to the waiting client.
    w.write_all(b"{\"id\":5,\"n\":\"oops\"}\n").unwrap();
    let mut lines = BufReader::new(conn).lines();
    match next_response(&mut lines) {
        WireResponse::Error { id, error, retry_after_ms } => {
            assert_eq!(id, 5, "best-effort id recovery from the bad line");
            assert!(error.starts_with("bad request:"), "{error}");
            assert_eq!(retry_after_ms, None, "a parse error is not a shed");
        }
        other => panic!("expected an error line, got {other:?}"),
    }
    // The same socket keeps working — one typo'd request must not kill
    // its neighbours on the connection.
    let req = WireRequest { id: 6, n: 3, seed: 0, key: PlanKey::gddim("vpsde", "gmm2d", 5, 1) };
    w.write_all(req.to_line().as_bytes()).unwrap();
    match next_response(&mut lines) {
        WireResponse::Result { id, dim_x, xs, .. } => {
            assert_eq!((id, dim_x), (6, 2));
            assert_eq!(xs.len(), 3 * 2);
            assert!(xs.iter().all(|x| x.is_finite()));
        }
        other => panic!("expected a result after the bad line, got {other:?}"),
    }

    let report = server.shutdown();
    let edge = report.edge.unwrap();
    assert_eq!(edge.requests_malformed, 1);
    assert_eq!(edge.requests_admitted, 1);
    assert_eq!(edge.requests_completed, 1);
}
