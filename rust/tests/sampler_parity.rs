//! Parity suite for the step-level `Sampler` trait redesign
//! (`cargo test --test sampler_parity`; CI also runs it under
//! `GDDIM_TEST_WORKERS=4`).
//!
//! Locks three equivalences for every one of the seven samplers:
//!
//! 1. the historical free functions and `Sampler::run` produce identical
//!    bytes (the wrappers delegate — this pins that they keep doing so);
//! 2. driving the state machine by hand through the `ScoreRequest`
//!    boundary — the engine's per-shard loop — matches `Sampler::run`;
//! 3. the engine's merged output is worker-count invariant for every
//!    sampler (the old suite only covered gDDIM + ancestral);
//! 4. the cross-key score scheduler (`score_batch > 0`) is bit-identical
//!    to the direct-call path for every sampler and worker count — the
//!    pooled `eps_batch` frontier may regroup rows, never change them —
//!    and the same holds with the learned `ScoreNet` backend (loaded
//!    from the committed fixture) in place of the oracle.
//!
//! Plus: the trait objects are Send/Sync (they cross pool threads), the
//! router serves every `SamplerSpec` variant end-to-end on vpsde/blobs8
//! (SSCS cleanly rejected off CLD), blobs16 serves on the registry-sized
//! BDM (vector data on BDM is a submit-time rejection), the d=1024
//! blobs32 preset is worker-count bit-identical under the default shard
//! byte budget on BDM and VPSDE, and λ survives a key round trip without
//! the old milli-unit truncation.

use std::sync::Arc;
use std::time::Duration;

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Cld, Process, TimeGrid};
use gddim::engine::{Engine, EngineConfig, Job};
use gddim::math::rng::Rng;
use gddim::samplers::{
    self, model_score, Ancestral, Em, GddimDet, GddimSde, Heun, OrderedF64, Rk45, Sampler,
    SampleOutput, SamplerSpec, Sscs,
};
use gddim::score::oracle::GmmOracle;
use gddim::score::ScoreModel;
use gddim::server::batcher::BatcherConfig;
use gddim::server::request::{GenRequest, PlanKey};
use gddim::server::router::{oracle_factory, Router};

const SEED: u64 = 0x5EED;
const N: usize = 48;

struct Fixture {
    proc: Arc<Cld>,
    oracle: GmmOracle,
    grid: TimeGrid,
    det_plan: SamplerPlan,
    pc_plan: SamplerPlan,
    sde_plan: SamplerPlan,
}

fn fixture() -> Fixture {
    let spec = presets::gmm2d();
    let proc = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
    let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
    let det_plan =
        SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let pc_plan = SamplerPlan::build(
        proc.as_ref(),
        &grid,
        &PlanConfig { q: 2, with_corrector: true, ..PlanConfig::default() },
    );
    let sde_plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::stochastic(0.5));
    Fixture { proc, oracle, grid, det_plan, pc_plan, sde_plan }
}

fn assert_bytes_equal(a: &SampleOutput, b: &SampleOutput, what: &str) {
    assert_eq!(a.xs, b.xs, "{what}: xs diverged");
    assert_eq!(a.us, b.us, "{what}: us diverged");
    assert_eq!(a.nfe, b.nfe, "{what}: NFE diverged");
}

/// Drive the state machine by hand — the exact loop the engine runs per
/// shard — and check it matches the default `run` driver bit for bit.
fn step_drive(
    sampler: &dyn Sampler,
    proc: &dyn Process,
    oracle: &GmmOracle,
    seed: u64,
) -> SampleOutput {
    let mut rng = Rng::seed_from(seed);
    let mut state = sampler.init(proc, oracle, N, &mut rng, false);
    let mut score = model_score(oracle);
    for i in (1..=sampler.n_steps()).rev() {
        state.step(i, &mut score, &mut rng);
    }
    state.finish()
}

fn parity_case(
    sampler: &dyn Sampler,
    free: SampleOutput,
    proc: &dyn Process,
    oracle: &GmmOracle,
    what: &str,
) {
    let via_run = sampler.run(proc, oracle, N, &mut Rng::seed_from(SEED), false);
    assert_bytes_equal(&free, &via_run, &format!("{what}: free fn vs Sampler::run"));
    let via_steps = step_drive(sampler, proc, oracle, SEED);
    assert_bytes_equal(&free, &via_steps, &format!("{what}: free fn vs step driver"));
}

#[test]
fn parity_gddim_deterministic_and_pc() {
    let f = fixture();
    for (what, plan) in [("gddim q=2", &f.det_plan), ("gddim q=2 PC", &f.pc_plan)] {
        let free = samplers::gddim::sample_deterministic(
            f.proc.as_ref(),
            plan,
            &f.oracle,
            N,
            &mut Rng::seed_from(SEED),
            false,
        );
        parity_case(&GddimDet { plan }, free, f.proc.as_ref(), &f.oracle, what);
    }
}

#[test]
fn parity_gddim_stochastic() {
    let f = fixture();
    let free = samplers::gddim::sample_stochastic(
        f.proc.as_ref(),
        &f.sde_plan,
        &f.oracle,
        N,
        &mut Rng::seed_from(SEED),
        false,
    );
    parity_case(&GddimSde { plan: &f.sde_plan }, free, f.proc.as_ref(), &f.oracle, "gddim-sde");
}

#[test]
fn parity_em() {
    let f = fixture();
    for lambda in [0.0, 1.0] {
        let free = samplers::em::sample_em(
            f.proc.as_ref(),
            &f.oracle,
            &f.grid,
            lambda,
            N,
            &mut Rng::seed_from(SEED),
            false,
        );
        let what = format!("em λ={lambda}");
        parity_case(&Em { grid: &f.grid, lambda }, free, f.proc.as_ref(), &f.oracle, &what);
    }
}

#[test]
fn parity_ancestral() {
    let f = fixture();
    let free = samplers::ancestral::sample_ancestral(
        f.proc.as_ref(),
        &f.oracle,
        &f.grid,
        N,
        &mut Rng::seed_from(SEED),
    );
    parity_case(&Ancestral { grid: &f.grid }, free, f.proc.as_ref(), &f.oracle, "ancestral");
}

#[test]
fn parity_heun() {
    let f = fixture();
    let free = samplers::heun::sample_heun(
        f.proc.as_ref(),
        &f.oracle,
        &f.grid,
        N,
        &mut Rng::seed_from(SEED),
    );
    parity_case(&Heun { grid: &f.grid }, free, f.proc.as_ref(), &f.oracle, "heun");
}

#[test]
fn parity_rk45() {
    let f = fixture();
    let free = samplers::rk45::sample_rk45(
        f.proc.as_ref(),
        &f.oracle,
        1e-3,
        N,
        &mut Rng::seed_from(SEED),
    );
    assert!(free.nfe > 0);
    parity_case(&Rk45 { rtol: 1e-3 }, free, f.proc.as_ref(), &f.oracle, "rk45");
}

#[test]
fn parity_sscs() {
    let f = fixture();
    let free = samplers::sscs::sample_sscs(
        f.proc.as_ref(),
        &f.oracle,
        &f.grid,
        N,
        &mut Rng::seed_from(SEED),
    );
    parity_case(&Sscs { grid: &f.grid }, free, f.proc.as_ref(), &f.oracle, "sscs");
}

/// The acceptance contract of the redesign: every sampler, served through
/// the engine, is bit-identical for any worker count (the old suite only
/// locked gDDIM and ancestral).
#[test]
fn engine_is_worker_count_invariant_for_all_seven_samplers() {
    let f = fixture();
    let cases: Vec<(&str, Box<dyn Sampler + '_>)> = vec![
        ("gddim", Box::new(GddimDet { plan: &f.det_plan })),
        ("gddim-pc", Box::new(GddimDet { plan: &f.pc_plan })),
        ("gddim-sde", Box::new(GddimSde { plan: &f.sde_plan })),
        ("em", Box::new(Em { grid: &f.grid, lambda: 1.0 })),
        ("ancestral", Box::new(Ancestral { grid: &f.grid })),
        ("heun", Box::new(Heun { grid: &f.grid })),
        ("rk45", Box::new(Rk45 { rtol: 1e-3 })),
        ("sscs", Box::new(Sscs { grid: &f.grid })),
    ];
    for (what, sampler) in &cases {
        let run = |workers: usize| {
            let cfg = EngineConfig { workers, shard_size: 16, ..EngineConfig::default() };
            Engine::with_config(cfg).run(&Job {
                proc: f.proc.as_ref(),
                model: &f.oracle,
                sampler: sampler.as_ref(),
                n: N, // 3 shards of 16
                seed: SEED,
            })
        };
        let one = run(1);
        assert!(one.xs.iter().all(|x| x.is_finite()), "{what}: non-finite output");
        for workers in [2usize, 4] {
            let multi = run(workers);
            assert_bytes_equal(&one, &multi, &format!("{what} @ {workers} workers"));
        }
    }
}

/// Dimension-scale bit-identity: the blobs32 preset (d = 1024, the
/// largest state the catalogue serves) on both the image-space BDM and
/// VPSDE must merge to identical bytes for 1/2/4 workers under the
/// engine's *default* dimension-aware shard budget (16 rows/shard at
/// dim_u = 1024). This is the worker-count contract of the 8×8 suite,
/// re-proved where the byte budget actually changes the layout.
#[test]
fn engine_bit_identity_at_d1024_blobs32() {
    let spec = presets::blobs32();
    assert_eq!(spec.d, 1024);
    let procs: Vec<Arc<dyn Process>> = vec![
        Arc::new(gddim::diffusion::Bdm::standard(32, 32)),
        Arc::new(gddim::diffusion::Vpsde::standard(1024)),
    ];
    for proc in procs {
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 6);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let sampler = GddimDet { plan: &plan };
        let run = |workers: usize| {
            let cfg = EngineConfig { workers, ..EngineConfig::default() };
            assert_eq!(cfg.rows_per_shard(proc.dim_u()), 16, "{}: budget rows", proc.name());
            Engine::with_config(cfg).run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &sampler,
                n: 40, // 3 shards of 16/16/8 under the default byte budget
                seed: SEED,
            })
        };
        let one = run(1);
        assert_eq!(one.xs.len(), 40 * 1024, "{}: output shape", proc.name());
        assert!(one.xs.iter().all(|x| x.is_finite()), "{}: non-finite output", proc.name());
        for workers in [2usize, 4] {
            let multi = run(workers);
            assert_bytes_equal(
                &one,
                &multi,
                &format!("blobs32 on {} @ {workers} workers", proc.name()),
            );
        }
    }
}

/// The cross-key scheduler's acceptance contract: for **every** sampler
/// spec in the suite and every worker count, pooled score execution
/// (`score_batch > 0`) is bit-identical to the direct-call path. The
/// scheduler may change which rows share an `eps_batch` call — never
/// any row's bytes, any RNG stream, or any NFE count.
#[test]
fn score_scheduler_is_bit_identical_for_every_sampler_and_worker_count() {
    let f = fixture();
    let cases: Vec<(&str, Box<dyn Sampler + '_>)> = vec![
        ("gddim", Box::new(GddimDet { plan: &f.det_plan })),
        ("gddim-pc", Box::new(GddimDet { plan: &f.pc_plan })),
        ("gddim-sde", Box::new(GddimSde { plan: &f.sde_plan })),
        ("em", Box::new(Em { grid: &f.grid, lambda: 1.0 })),
        ("ancestral", Box::new(Ancestral { grid: &f.grid })),
        ("heun", Box::new(Heun { grid: &f.grid })),
        ("rk45", Box::new(Rk45 { rtol: 1e-3 })),
        ("sscs", Box::new(Sscs { grid: &f.grid })),
    ];
    for (what, sampler) in &cases {
        let run = |workers: usize, score_batch: usize| {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 16,
                score_batch,
                score_wait: Duration::from_millis(50),
                ..EngineConfig::default()
            });
            let out = engine.run(&Job {
                proc: f.proc.as_ref(),
                model: &f.oracle,
                sampler: sampler.as_ref(),
                n: N, // 3 shards of 16
                seed: SEED,
            });
            if score_batch > 0 {
                let stats = engine.stats();
                assert!(stats.score_calls > 0, "{what}: scheduler must carry all score calls");
                assert!(stats.score_rows > 0, "{what}: pooled rows must be counted");
            }
            out
        };
        let reference = run(1, 0);
        for workers in [1usize, 2, 4] {
            let pooled = run(workers, 4096);
            assert_bytes_equal(
                &reference,
                &pooled,
                &format!("{what} scheduler-on @ {workers} workers"),
            );
        }
    }
}

/// The scheduler contract re-proved on the learned backend: a real
/// `ScoreNet` forward (matmuls + FiLM from the committed tiny-model
/// fixture, not a closed-form oracle) pooled through the cross-key
/// frontier must produce the same bytes as the direct-call path for
/// every worker count — exactly what the k-outer `axpy` layout in
/// `score::net` exists to guarantee.
#[test]
fn score_scheduler_is_bit_identical_for_the_learned_backend() {
    let reg = gddim::score::ModelRegistry::open(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/learned"
    ))
    .expect("committed fixture loads");
    let net = reg.get("tiny_vpsde_gmm2d").unwrap();
    let proc = Arc::new(gddim::diffusion::Vpsde::standard(net.dim_u()));
    let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
    let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let sampler = GddimDet { plan: &plan };
    let run = |workers: usize, score_batch: usize| {
        Engine::with_config(EngineConfig {
            workers,
            shard_size: 16,
            score_batch,
            score_wait: Duration::from_millis(50),
            ..EngineConfig::default()
        })
        .run(&Job {
            proc: proc.as_ref(),
            model: net.as_ref(),
            sampler: &sampler,
            n: N, // 3 shards of 16
            seed: SEED,
        })
    };
    let reference = run(1, 0);
    assert!(reference.xs.iter().all(|x| x.is_finite()), "learned backend: non-finite output");
    for workers in [1usize, 2, 4] {
        let pooled = run(workers, 4096);
        assert_bytes_equal(&reference, &pooled, &format!("learned net @ {workers} workers"));
    }
}

/// Trait-object audit: samplers and their states cross engine pool
/// threads by reference, so the bounds are load-bearing, not stylistic.
#[test]
fn sampler_trait_objects_are_send_sync() {
    fn assert_send_sync<T: ?Sized + Send + Sync>() {}
    fn assert_send<T: ?Sized + Send>() {}
    assert_send_sync::<dyn Sampler>();
    assert_send::<dyn samplers::SamplerState>();
    assert_send_sync::<SamplerSpec>();
    assert_send_sync::<PlanKey>();
    assert_send_sync::<GddimDet<'_>>();
    assert_send_sync::<GddimSde<'_>>();
    assert_send_sync::<Em<'_>>();
    assert_send_sync::<Ancestral<'_>>();
    assert_send_sync::<Heun<'_>>();
    assert_send_sync::<Rk45>();
    assert_send_sync::<Sscs<'_>>();
    assert_send_sync::<Box<dyn Sampler>>();
}

/// Every `SamplerSpec` variant is servable through `Router::submit` —
/// including the three the old `SamplerKind` could not express (heun,
/// rk45, sscs) — on the 64-dim vpsde/blobs8 image path, with SSCS
/// rejected cleanly off CLD and served on CLD.
#[test]
fn router_serves_every_spec_variant_on_vpsde_blobs8() {
    let router = Router::new(2, BatcherConfig::default(), oracle_factory());
    let servable = [
        SamplerSpec::GddimDet { q: 2, kt: KtKind::R, corrector: false },
        SamplerSpec::GddimSde { lambda: OrderedF64::new(0.5) },
        SamplerSpec::Em { lambda: OrderedF64::new(1.0) },
        SamplerSpec::Ancestral,
        SamplerSpec::Heun,
        SamplerSpec::Rk45 { rtol: OrderedF64::new(1e-2) },
    ];
    for (id, spec) in servable.into_iter().enumerate() {
        let key = PlanKey::new("vpsde", "blobs8", spec.clone(), 6);
        let rx = router.submit(GenRequest { id: id as u64, n: 4, key, seed: id as u64 });
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{spec} rejected: {:?}", resp.error);
        assert_eq!(resp.xs.len(), 4 * 64, "{spec}: wrong sample shape");
        assert!(resp.xs.iter().all(|x| x.is_finite()), "{spec}: non-finite samples");
        assert!(resp.nfe > 0, "{spec}: NFE not reported");
    }
    // SSCS: clean rejection off CLD, service on CLD.
    let rx = router.submit(GenRequest {
        id: 100,
        n: 4,
        key: PlanKey::new("vpsde", "blobs8", SamplerSpec::Sscs, 6),
        seed: 1,
    });
    let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert!(resp.error.is_some(), "sscs off CLD must be rejected");
    let rx = router.submit(GenRequest {
        id: 101,
        n: 8,
        key: PlanKey::new("cld", "gmm2d", SamplerSpec::Sscs, 6),
        seed: 1,
    });
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(resp.error.is_none(), "sscs on CLD rejected: {:?}", resp.error);
    assert!(resp.xs.iter().all(|x| x.is_finite()));
    router.shutdown();
}

/// The wider-data-scale service contract: a 16×16 preset round-trips
/// through the router on the image-space BDM (the factory sizes BDM
/// from the registry's `(h, w)`, not a `sqrt(d)` guess), while vector
/// data on BDM is rejected at submit time instead of panicking a
/// dispatcher inside the oracle's dimension assert.
#[test]
fn router_serves_blobs16_on_bdm_and_rejects_bdm_on_vector_data() {
    let router = Router::new(2, BatcherConfig::default(), oracle_factory());
    for (id, dataset, d) in [(0u64, "blobs16", 256usize), (1, "blobs8", 64)] {
        let key = PlanKey::gddim("bdm", dataset, 6, 2);
        let rx = router.submit(GenRequest { id, n: 4, key, seed: id });
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{dataset} on bdm rejected: {:?}", resp.error);
        assert_eq!(resp.xs.len(), 4 * d, "{dataset}: wrong sample shape");
        assert_eq!(resp.dim_x, d);
        assert!(resp.xs.iter().all(|x| x.is_finite()), "{dataset}: non-finite samples");
    }
    let rx = router.submit(GenRequest {
        id: 9,
        n: 4,
        key: PlanKey::gddim("bdm", "gmm2d", 6, 2),
        seed: 0,
    });
    let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert!(resp.error.is_some(), "2-D vector data on bdm must be a clean rejection");
    assert!(resp.xs.is_empty());
    router.shutdown();
}

/// λ regression: the old key stored λ×1000 in a u32, so λ=0.0001 aliased
/// λ=0 and the two configurations shared one batch (and one plan). The
/// owned spec must keep them distinct end to end.
#[test]
fn lambda_precision_survives_the_key_end_to_end() {
    let tiny = PlanKey::new(
        "vpsde",
        "gmm2d",
        SamplerSpec::Em { lambda: OrderedF64::new(0.0001) },
        6,
    );
    let zero =
        PlanKey::new("vpsde", "gmm2d", SamplerSpec::Em { lambda: OrderedF64::new(0.0) }, 6);
    assert_ne!(tiny, zero, "λ=0.0001 must not alias λ=0");
    match &tiny.spec {
        SamplerSpec::Em { lambda } => assert_eq!(lambda.get().to_bits(), 0.0001f64.to_bits()),
        _ => unreachable!(),
    }
    // Both keys are served as distinct batches.
    let router = Router::new(2, BatcherConfig::default(), oracle_factory());
    let ra = router.submit(GenRequest { id: 0, n: 8, key: tiny, seed: 3 });
    let rb = router.submit(GenRequest { id: 1, n: 8, key: zero, seed: 3 });
    let a = ra.recv_timeout(Duration::from_secs(60)).unwrap();
    let b = rb.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(a.error.is_none() && b.error.is_none());
    assert_eq!(a.batch_size, 1);
    assert_eq!(b.batch_size, 1);
    router.shutdown();
}
