#!/usr/bin/env bash
# Refresh the committed perf trajectory: run the serving bench, write a
# fresh BENCH_serving.json at the repo root, and print a benchdiff
# against the copy committed at HEAD.
#
# Usage:
#   scripts/bench_commit.sh            # full bench (minutes)
#   GDDIM_BENCH_QUICK=1 scripts/bench_commit.sh   # CI-probe sizes (seconds)
#
# Numbers are machine-dependent — the committed baseline comes from CI's
# runner class (see README "Performance trajectory"), so a local diff is
# informational unless your box matches it. The script never fails on a
# regression verdict; it fails only if the bench or schema check breaks.

set -euo pipefail
cd "$(dirname "$0")/.."

OUT=BENCH_serving.json
export GDDIM_BENCH_SOURCE="${GDDIM_BENCH_SOURCE:-local}"

OLD=""
if git cat-file -e "HEAD:$OUT" 2>/dev/null; then
    OLD=$(mktemp --suffix=.json)
    trap 'rm -f "$OLD"' EXIT
    git show "HEAD:$OUT" > "$OLD"
fi

cargo bench --bench serving -- --json "$OUT"
cargo run --release --bin gddim -- benchdiff --validate "$OUT"

if [ -n "$OLD" ]; then
    # Advisory: print the comparison but do not fail the refresh on it.
    cargo run --release --bin gddim -- benchdiff "$OLD" "$OUT" || true
else
    echo "no $OUT committed at HEAD — wrote the first snapshot"
fi

echo "refreshed $OUT — commit it alongside your change"
